"""Drivers for the powercap Pallas kernels (executor entry points).

The dispatchers in ``repro.drs.entitlement`` / ``repro.core.kernels`` call
these when the ``jax-pallas`` executor is active (``repro.backend.
pallas_enabled()``):

  * :func:`pallas_waterfill_dense`   -- drop-in for ``waterfill_dense`` on
    the JAX plane: one grid step per scenario cell over ``(S, H, J)``.
  * :func:`pallas_balance_caps`      -- the whole BalancePowerCap loop with
    the fused balance-round + waterfill kernel as the ``while_loop`` body.
  * :func:`pallas_waterfill_segmented` -- drop-in for the segmented
    (``seg_ids``) waterfill entry points: ragged host/VM counts via a CSR
    layout, one grid step per host, no ``H * J`` dense padding.

Interpret-mode fallback: off-TPU (``jax.default_backend() != "tpu"``) the
kernels run under ``pl.pallas_call(..., interpret=True)``, where they
execute the same jnp op sequence as the lax executor and are bit-identical
to it in float64.  ``REPRO_PALLAS_INTERPRET=0/1`` overrides the automatic
choice (e.g. to force-compile on a TPU-less CI runner, or to interpret on
TPU while debugging).
"""

from __future__ import annotations

import functools
import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import kernels as core_kernels
from repro.kernels.powercap import kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interpret_mode() -> bool:
    """Whether the kernels run in interpret mode (auto: off-TPU)."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env.lower() not in ("0", "false", "no")
    return not _on_tpu()


# ------------------------------------------------------- dense waterfill
@functools.partial(jax.jit, static_argnames=("iters", "interpret"))
def _dense_call(capacity, floors, ceilings, weights, active, *, iters,
                interpret):
    return kernel.waterfill_call(capacity, floors, ceilings, weights,
                                 active, iters=iters, interpret=interpret)


def pallas_waterfill_dense(capacity, floors, ceilings, weights,
                           iters: int = 200, active=None):
    """Pallas twin of ``waterfill_dense_math`` (same shape contract:
    ``capacity (..., H)``, slot columns ``(..., H, J)``)."""
    fl = jnp.asarray(floors)
    ce = jnp.asarray(ceilings)
    w = jnp.asarray(weights)
    act = (jnp.ones(fl.shape, bool) if active is None
           else jnp.asarray(active, bool))
    lead = fl.shape[:-2]
    h, j = fl.shape[-2:]
    if h == 0 or j == 0 or 0 in lead:
        return jnp.zeros(fl.shape, fl.dtype)
    cap = jnp.broadcast_to(jnp.asarray(capacity), lead + (h,))
    out = _dense_call(cap.reshape((-1, h)), fl.reshape((-1, h, j)),
                      ce.reshape((-1, h, j)), w.reshape((-1, h, j)),
                      act.reshape((-1, h, j)), iters=iters,
                      interpret=interpret_mode())
    return out.reshape(fl.shape)


# ----------------------------------------------------- fused balance loop
@functools.partial(jax.jit,
                   static_argnames=("iters", "params", "interpret"))
def _balance_loop(hosts, caps, fl, ce, w, act, cpu_reserved, budget,
                  enabled, *, iters, params, interpret):
    on = hosts.on
    n_on = jnp.sum(on, axis=-1)
    peak_managed = core_kernels.peak_managed_capacity(jnp, hosts)
    managed = core_kernels.managed_capacity(jnp, hosts, caps)
    alloc = kernel.waterfill_call(managed, fl, ce, w, act, iters=iters,
                                  interpret=interpret)
    ents = jnp.sum(alloc, axis=-1)
    ns = jnp.where(managed > 0.0, ents / jnp.maximum(managed, 1e-300), 0.0)
    done0 = ~enabled | (n_on < 2)
    did0 = jnp.zeros_like(done0)

    def cond(state):
        return (state[-1] < params.max_iters) & ~jnp.all(state[4])

    def body(state):
        caps, managed, ents, ns, done, did, rounds = state
        out = kernel.balance_round_call(
            hosts, (fl, ce, w, act), cpu_reserved, budget, n_on,
            peak_managed, (caps, managed, ents, ns, done, did),
            iters=iters, params=params, interpret=interpret)
        return (*out, rounds + 1)

    state = (caps, managed, ents, ns, done0, did0, 0)
    caps, _, _, _, _, did, _ = jax.lax.while_loop(cond, body, state)
    return caps, did


def pallas_balance_caps(hosts, caps, dense, cpu_reserved, budget, enabled,
                        params):
    """Pallas driver for the BalancePowerCap loop.

    Mirrors ``repro.core.kernels.balance_caps`` on the JAX plane, with the
    per-round math running as the fused kernel; ``dense`` is the
    ``DenseCols`` bundle describing the same entitlement problem as the
    caller's ``ents_at`` closure (which this driver replaces).
    """
    caps = jnp.asarray(caps)
    s, h = caps.shape
    fl = jnp.asarray(dense.floors)
    ce = jnp.asarray(dense.ceils)
    w = jnp.asarray(dense.weights)
    act = jnp.asarray(dense.active, bool)
    if s == 0 or h == 0:
        return caps, jnp.zeros(jnp.shape(enabled), bool)
    if fl.shape[-1] == 0:
        # No slots: pad one inactive slot so the kernel grid is well formed
        # (the masked slot allocates nothing).
        pad = ((0, 0),) * (fl.ndim - 1) + ((0, 1),)
        fl, ce, w = (jnp.pad(c, pad) for c in (fl, ce, w))
        act = jnp.pad(act, pad)
    return _balance_loop(hosts, caps, fl, ce, w, act, cpu_reserved,
                         budget, enabled, iters=int(dense.iters),
                         params=params, interpret=interpret_mode())


# ---------------------------------------------------- segmented waterfill
@functools.partial(jax.jit,
                   static_argnames=("n", "iters", "jb", "interpret"))
def _segmented_call(capacity, starts, counts, fl, ce, w, seg_sorted, slot,
                    perm, *, n, iters, jb, interpret):
    dense = kernel.segmented_call(capacity, starts, counts, fl, ce, w,
                                  iters=iters, jb=jb, interpret=interpret)
    alloc_sorted = dense[seg_sorted, slot]
    return jnp.zeros((n,), fl.dtype).at[perm].set(alloc_sorted)


def _jb_for(max_count: int) -> int:
    """Static window width: next power of two (>= 4) covering the longest
    row, so recompiles happen on row-length doublings, not every call."""
    jb = 4
    while jb < max_count:
        jb *= 2
    return jb


def pallas_waterfill_segmented(capacity, floors, ceilings, weights,
                               seg_ids, n_segs: int, iters: int = 200):
    """Segmented (ragged) waterfill: flat item arrays plus ``seg_ids``.

    CSR layout built eagerly (inputs must be concrete, as in the NumPy and
    test callers): items are stably sorted by segment, each host's window
    ``[start, start + count)`` is processed by one grid step with a
    ``JB``-wide dynamic slice, and the per-host rows are scattered back to
    the original item order.  Per-host math is the dense primitive, so the
    result matches ``waterfill_core`` to reduction-order rounding.
    """
    from jax.experimental import enable_x64

    capacity = np.asarray(capacity, dtype=np.float64)
    floors = np.asarray(floors, dtype=np.float64)
    ceilings = np.asarray(ceilings, dtype=np.float64)
    weights = np.asarray(weights, dtype=np.float64)
    seg_ids = np.asarray(seg_ids, dtype=np.int64)
    n = floors.shape[0]
    if n == 0 or n_segs == 0:
        return jnp.zeros((n,), jnp.float64)
    srt = np.argsort(seg_ids, kind="stable")
    seg_sorted = seg_ids[srt]
    counts = np.bincount(seg_sorted, minlength=n_segs).astype(np.int64)
    starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
    jb = _jb_for(int(counts.max()))
    pad = np.zeros(jb, dtype=np.float64)
    slot = np.arange(n, dtype=np.int64) - starts[seg_sorted]
    with enable_x64():
        return _segmented_call(
            jnp.asarray(capacity), jnp.asarray(starts), jnp.asarray(counts),
            jnp.asarray(np.concatenate([floors[srt], pad])),
            jnp.asarray(np.concatenate([ceilings[srt], pad])),
            jnp.asarray(np.concatenate([weights[srt], pad + 1e-12])),
            jnp.asarray(seg_sorted), jnp.asarray(slot), jnp.asarray(srt),
            n=n, iters=iters, jb=jb, interpret=interpret_mode())
