"""Grouped expert GEMM, Pallas TPU.

The MoE layer batches per-expert token buckets into (E, C, D) and applies
per-expert weights (E, D, F).  This is a batched matmul whose batch dim is
the (mesh-sharded) expert axis; the kernel tiles (C, D, F) into MXU-aligned
blocks with a VMEM f32 accumulator across the K (=D) grid dimension.

Tiling (defaults 128x512x128): per grid cell
  x (bc, bd) bf16 + w (bd, bf) bf16 + acc (bc, bf) f32
  = 128*512*2 + 512*128*2 + 128*128*4 ~ 0.33 MB  -- double-buffer friendly.

The K axis is innermost so the accumulator persists across K steps; output
is written once on the last K step (revolving-accumulator pattern).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_scr):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())))

    @pl.when(ik == nk - 1)
    def finalize():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_c", "block_d", "block_f", "interpret"))
def grouped_matmul_kernel(x, w, *, block_c: int = 128, block_d: int = 512,
                          block_f: int = 128, interpret: bool = False):
    """x: (E, C, D), w: (E, D, F) -> (E, C, F)."""
    e, c, d = x.shape
    f = w.shape[-1]
    bc, bd, bf = min(block_c, c), min(block_d, d), min(block_f, f)

    def pad_to(t, axis, mult):
        size = t.shape[axis]
        rem = (-size) % mult
        if rem:
            pads = [(0, 0)] * t.ndim
            pads[axis] = (0, rem)
            t = jnp.pad(t, pads)
        return t

    xp = pad_to(pad_to(x, 1, bc), 2, bd)
    wp = pad_to(pad_to(w, 1, bd), 2, bf)
    cp, dp, fp = xp.shape[1], xp.shape[2], wp.shape[2]

    out = pl.pallas_call(
        _gmm_kernel,
        grid=(e, cp // bc, fp // bf, dp // bd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda ie, ic, jf, kd: (ie, ic, kd)),
            pl.BlockSpec((1, bd, bf), lambda ie, ic, jf, kd: (ie, kd, jf)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf),
                               lambda ie, ic, jf, kd: (ie, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((e, cp, fp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(xp, wp)
    return out[:, :c, :f]
