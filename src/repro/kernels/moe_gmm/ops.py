"""Public grouped-matmul op: Pallas on TPU, interpret elsewhere."""

from __future__ import annotations

import jax

from repro.kernels.moe_gmm.kernel import grouped_matmul_kernel
from repro.kernels.moe_gmm import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def grouped_matmul(x, w, *, interpret: bool | None = None, **blocks):
    if interpret is None:
        interpret = not _on_tpu()
    return grouped_matmul_kernel(x, w, interpret=interpret, **blocks)


grouped_matmul_ref = _ref.grouped_matmul_ref
