from repro.kernels.moe_gmm.ops import grouped_matmul

__all__ = ["grouped_matmul"]
