"""Pallas TPU kernels for the data plane's compute hot spots.

Each kernel is a subpackage with:
  kernel.py -- pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    -- jit'd public wrapper (auto-interpret off-TPU)
  ref.py    -- pure-jnp oracle the tests assert against

CloudPowerCap itself is a control-plane technique (no kernel-level
contribution in the paper); these kernels serve the training/serving data
plane the power manager drives: flash attention (GQA causal, forward AND
backward via custom VJP), flash-decoding (split-K single-token attention
over ragged caches), the Mamba2 SSD intra-chunk scan, and the MoE grouped
expert GEMM.
"""
