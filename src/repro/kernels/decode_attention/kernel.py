"""Flash-decoding, Pallas TPU: one query token against a long (ragged) KV
cache, split-K style.

Unlike the training kernel (sequential online softmax over KV blocks), the
decode kernel emits *independent per-KV-block partials* (o, m, l) -- the
grid's KV dimension carries no cross-iteration state, so blocks can be
scheduled across both TensorCores / sliced across devices, which is what
hides HBM latency when the cache (not compute) is the bottleneck.  The tiny
log-sum-exp combine over partials runs in plain JAX.

Per-batch ``kv_len`` masks the unwritten cache tail (continuous batching:
every row decodes at a different position).

VMEM per cell at bk=512, d<=256, G<=48 f32:
  k/v (512, d) + q (G, d) + s/p (G, 512) ~ 1.3 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, *,
                   scale: float, block_k: int, seq: int):
    ik = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    kv_len = len_ref[0, 0]                         # scalar int32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    s = jnp.where((k_pos < kv_len) & (k_pos < seq), s, NEG_INF)
    m = s.max(axis=-1)                             # (G,)
    p = jnp.exp(s - m[:, None])
    # Fully-masked blocks (beyond kv_len): exp(NEG_INF - NEG_INF) = 1 junk;
    # zero them via the mask on l and o.
    p = jnp.where(m[:, None] <= NEG_INF / 2, 0.0, p)
    l = p.sum(axis=-1)
    o = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
    o_ref[0, 0, 0] = o
    m_ref[0, 0, 0] = m
    l_ref[0, 0, 0] = l


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention_kernel(q, k, v, kv_len, *, block_k: int = 512,
                            interpret: bool = False):
    """q: (B, Hq, D); k/v: (B, S, Hkv, D); kv_len: (B,) int32.

    Returns (B, Hq, D) in q.dtype."""
    b, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / np.sqrt(d)
    block_k = min(block_k, s)
    nk = pl.cdiv(s, block_k)
    pad = nk * block_k - s

    qg = q.reshape(b, hkv, g, d)
    kt = jnp.swapaxes(k, 1, 2)                     # (B, Hkv, S, D)
    vt = jnp.swapaxes(v, 1, 2)
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    len2 = kv_len.astype(jnp.int32).reshape(b, 1)

    o_p, m_p, l_p = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                          seq=s),
        grid=(b, hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda ib, ih, ik: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, ik: (ib, ih, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, ik: (ib, ih, ik, 0)),
            pl.BlockSpec((1, 1), lambda ib, ih, ik: (ib, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, g, d),
                         lambda ib, ih, ik: (ib, ih, ik, 0, 0)),
            pl.BlockSpec((1, 1, 1, g), lambda ib, ih, ik: (ib, ih, ik, 0)),
            pl.BlockSpec((1, 1, 1, g), lambda ib, ih, ik: (ib, ih, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, nk, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, nk, g), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, nk, g), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kt, vt, len2)

    # Log-sum-exp combine across KV blocks (split-K reduction, tiny).
    m_max = m_p.max(axis=2, keepdims=True)                 # (B,Hkv,1,G)
    alpha = jnp.exp(m_p - m_max)
    l_tot = (l_p * alpha).sum(axis=2)                      # (B,Hkv,G)
    o_tot = (o_p * alpha[..., None]).sum(axis=2)           # (B,Hkv,G,D)
    out = o_tot / jnp.maximum(l_tot, 1e-30)[..., None]
    return out.reshape(b, hq, d).astype(q.dtype)
