"""Public flash-decoding op: Pallas on TPU, interpret elsewhere."""

from __future__ import annotations

import jax

from repro.kernels.decode_attention import ref as _ref
from repro.kernels.decode_attention.kernel import decode_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode_attention(q, k, v, kv_len, *, block_k: int = 512,
                     interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    return decode_attention_kernel(q, k, v, kv_len, block_k=block_k,
                                   interpret=interpret)


decode_attention_ref = _ref.decode_attention_ref
