"""Pure-jnp oracle: single-token attention over a ragged KV cache."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def decode_attention_ref(q, k, v, kv_len):
    """q: (B, Hq, D); k/v: (B, S, Hkv, D); kv_len: (B,) valid prefix.

    Returns (B, Hq, D) in q.dtype."""
    b, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg,
                        k.astype(jnp.float32)) / np.sqrt(d)
    mask = jnp.arange(s)[None, :] < kv_len[:, None]          # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    p = jnp.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, d).astype(q.dtype)
